"""qwen2-7b — dense LM, GQA kv=4, QKV bias. [arXiv:2407.10671]."""
from repro.configs import base, register


def config():
    return base.LMConfig(
        arch_id="qwen2-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )


def shapes():
    return base.lm_shapes("qwen2-7b", full_attention_only=True)


register("qwen2-7b", config, shapes)
