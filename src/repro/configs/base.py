"""Config dataclasses for every architecture family the framework supports.

Configs are plain frozen dataclasses — data only, no jax imports — so that
importing a config never touches device state. ``input_specs`` /step builders
live in ``repro.launch``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell for an architecture.

    kind:
      lm_train    — train_step(tokens (B,S))
      lm_prefill  — serve_prefill(tokens (B,S)) -> logits + kv cache
      lm_decode   — serve_decode(cache seq=S, one new token)
      gnn_train   — train_step over a (padded) graph
      rec_train   — train_step over a recsys batch
      rec_serve   — pointwise inference batch
      retrieval   — 1 query vs n_candidates scoring
    """
    name: str
    kind: str
    dims: dict
    # If the cell is inapplicable for this arch, give the reason (DESIGN.md
    # §Arch-applicability); dryrun reports it as SKIP, not failure.
    skip: Optional[str] = None


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class LMConfig:
    arch_id: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    family: str = "lm"
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # Attention pattern: "G" = global full attention, "L" = sliding window.
    # Empty tuple = all global. Length must equal n_layers when set.
    layer_pattern: Tuple[str, ...] = ()
    window_size: int = 0
    moe: Optional[MoESpec] = None
    norm_eps: float = 1e-6
    param_dtype: str = "float32"     # master params
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    optimizer: str = "adamw"
    tie_embeddings: bool = False
    # attention chunk size for the jnp online-softmax path
    attn_chunk: int = 1024

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern:
            assert len(self.layer_pattern) == self.n_layers
            return self.layer_pattern
        return ("G",) * self.n_layers

    def n_params(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        else:
            ff = 3 * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ff + 2 * d) + emb + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.n_heads * self.head_dim * d
        ff = self.moe.top_k * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ff + 2 * d) + emb + d


# ---------------------------------------------------------------------------
# Dual encoder (the paper's own architecture: BERT-base geometry)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DualEncoderConfig:
    arch_id: str = "list-dual-encoder"
    family: str = "dual_encoder"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 32_768      # hashing tokenizer vocab
    max_len: int = 64
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    optimizer: str = "adamw"

    # --- LIST-specific hyperparameters (paper Table 2) ---
    spatial_t: int = 1000          # step-function resolution
    n_clusters: int = 20           # c  (n/10k rule)
    cluster_route: int = 1         # cr
    neg_start: int = 50_000
    neg_end: int = 55_000
    hard_neg_b: int = 4            # b hard negatives per query (Eq. 8)
    mcl_negatives: int = 8         # m negatives per query for MCL (Eq. 14)
    index_mlp_hidden: Tuple[int, ...] = (512, 512)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GNNConfig:
    arch_id: str
    n_layers: int = 16
    d_hidden: int = 70
    aggregator: str = "gated"      # GatedGCN
    family: str = "gnn"
    dropout: float = 0.0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    optimizer: str = "adamw"
    residual: bool = True
    norm: str = "layer"            # per-layer norm on node/edge states


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DLRMConfig:
    arch_id: str = "dlrm-mlperf"
    family: str = "recsys"
    model: str = "dlrm"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    interaction: str = "dot"
    # Criteo-1TB row counts capped at 40M per MLPerf reference (--max-ind-range).
    table_sizes: Tuple[int, ...] = (
        40_000_000, 39_060, 17_295, 7_424, 20_265, 3, 7_122, 1_543, 63,
        40_000_000, 3_067_956, 405_282, 10, 2_209, 11_938, 155, 4, 976, 14,
        40_000_000, 40_000_000, 40_000_000, 590_152, 12_973, 108, 36)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    optimizer: str = "adamw"


@dataclass(frozen=True)
class XDeepFMConfig:
    arch_id: str = "xdeepfm"
    family: str = "recsys"
    model: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp: Tuple[int, ...] = (400, 400)
    vocab_per_field: int = 200_000
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    optimizer: str = "adamw"


@dataclass(frozen=True)
class BERT4RecConfig:
    arch_id: str = "bert4rec"
    family: str = "recsys"
    model: str = "bert4rec"
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    n_items: int = 1_000_000
    d_ff: int = 256
    mask_prob: float = 0.2
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    optimizer: str = "adamw"


@dataclass(frozen=True)
class MINDConfig:
    arch_id: str = "mind"
    family: str = "recsys"
    model: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    n_items: int = 1_000_000
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    optimizer: str = "adamw"


# ---------------------------------------------------------------------------
# Shared shape tables (per system prompt)
# ---------------------------------------------------------------------------

def lm_shapes(arch_id: str, *, full_attention_only: bool) -> Tuple[ShapeSpec, ...]:
    long_skip = None
    if full_attention_only:
        long_skip = ("pure full-attention arch: 500k-context decode requires "
                     "sub-quadratic attention / bounded KV (DESIGN.md §7)")
    return (
        ShapeSpec("train_4k", "lm_train", dict(seq_len=4096, global_batch=256)),
        ShapeSpec("prefill_32k", "lm_prefill", dict(seq_len=32768, global_batch=32)),
        ShapeSpec("decode_32k", "lm_decode", dict(seq_len=32768, global_batch=128)),
        ShapeSpec("long_500k", "lm_decode", dict(seq_len=524288, global_batch=1),
                  skip=long_skip),
    )


GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "gnn_train",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    ShapeSpec("minibatch_lg", "gnn_train",
              dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
                   fanout=(15, 10), d_feat=602, n_classes=41, sampled=True)),
    ShapeSpec("ogb_products", "gnn_train",
              dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                   n_classes=47)),
    ShapeSpec("molecule", "gnn_train",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, n_classes=1,
                   batched=True)),
)

REC_SHAPES = (
    ShapeSpec("train_batch", "rec_train", dict(batch=65_536)),
    ShapeSpec("serve_p99", "rec_serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "rec_serve", dict(batch=262_144)),
    ShapeSpec("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
)


def reduced(cfg):
    """Return a small config of the same family for CPU smoke tests."""
    if isinstance(cfg, LMConfig):
        kw = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
            head_dim=16, d_ff=128, vocab_size=512, scan_layers=True, remat=False,
            attn_chunk=32,
        )
        if cfg.layer_pattern:
            kw["layer_pattern"] = ("L", "G")
            kw["window_size"] = 16
        if cfg.moe is not None:
            kw["moe"] = MoESpec(n_experts=4, top_k=2, d_ff_expert=32,
                                capacity_factor=cfg.moe.capacity_factor)
        return dataclasses.replace(cfg, **kw)
    if isinstance(cfg, DualEncoderConfig):
        return dataclasses.replace(
            cfg, n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=128,
            max_len=16, spatial_t=50, n_clusters=4, neg_start=20, neg_end=30,
            index_mlp_hidden=(32,))
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(cfg, n_layers=3, d_hidden=16)
    if isinstance(cfg, DLRMConfig):
        return dataclasses.replace(
            cfg, embed_dim=16, bot_mlp=(32, 16), top_mlp=(64, 32, 1),
            table_sizes=tuple([100] * 26))
    if isinstance(cfg, XDeepFMConfig):
        return dataclasses.replace(cfg, embed_dim=8, cin_layers=(16, 16),
                                   mlp=(32, 32), vocab_per_field=100)
    if isinstance(cfg, BERT4RecConfig):
        return dataclasses.replace(cfg, embed_dim=16, n_blocks=2, n_heads=2,
                                   seq_len=16, n_items=200, d_ff=32)
    if isinstance(cfg, MINDConfig):
        return dataclasses.replace(cfg, embed_dim=16, n_interests=2,
                                   hist_len=8, n_items=200)
    raise TypeError(f"unknown config type {type(cfg)}")
