"""gemma3-27b — dense LM, 5:1 local:global sliding-window hybrid, 128k context.

[hf:google/gemma-3-*; config per assignment table]. head_dim decoupled from
d_model (Gemma-3 convention, 128). Window 1024 for local layers.
"""
from repro.configs import base, register

_N_LAYERS = 62
# 5 local : 1 global, remainder local (62 = 10*6 + 2).
_PATTERN = tuple((["L"] * 5 + ["G"]) * 10 + ["L", "L"])


def config():
    return base.LMConfig(
        arch_id="gemma3-27b",
        n_layers=_N_LAYERS,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262_144,
        layer_pattern=_PATTERN,
        window_size=1024,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def shapes():
    # Hybrid sliding-window arch: long_500k RUNS (local KV bounded by window;
    # global layers decode linearly in cache length). See DESIGN.md §7.
    return base.lm_shapes("gemma3-27b", full_attention_only=False)


register("gemma3-27b", config, shapes)
