"""bert4rec — bidirectional sequential recommender. [arXiv:1904.06690]."""
from repro.configs import base, register


def config():
    return base.BERT4RecConfig()


def shapes():
    return base.REC_SHAPES


register("bert4rec", config, shapes)
