"""Optimizers (AdamW, Adafactor), LR schedules, gradient clipping.

Self-contained functional optimizers (no optax dependency): each is
``init(params) -> state`` + ``update(grads, state, params, lr) ->
(new_params, new_state)``. States are pytrees so they shard/checkpoint
exactly like params.

Adafactor keeps factored second moments (row/col) for >=2-D leaves, which is
what makes the 1T-param kimi-k2 optimizer state fit HBM (DESIGN.md §5).
"""
from repro.optim.optimizers import (  # noqa: F401
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)
from repro.optim.schedules import (  # noqa: F401
    constant_lr,
    cosine_schedule,
    linear_warmup_cosine,
    linear_warmup_linear_decay,
)
