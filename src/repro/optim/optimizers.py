"""AdamW and Adafactor, functional form, pytree states."""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (dim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    p_new = jax.tree.map(lambda t3: t3[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t3: t3[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t3: t3[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return p_new, {"step": step, "m": m_new, "v": v_new}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; beta1=0 — no first moment)
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def leaf(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),          # row
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "step": jnp.zeros((), jnp.int32),
        "v": jax.tree.map(leaf, params,
                          is_leaf=lambda x: isinstance(x, jax.Array)),
    }


def adafactor_update(grads, state, params, lr, *, decay_pow=0.8,
                     eps=1e-30, clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - jnp.power(t, -decay_pow)

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if _factored(p.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            # rank-1 reconstruction of the second moment
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            u = g32 * jax.lax.rsqrt(r[..., None] * vc[..., None, :] + eps)
            v_new = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            u = g32 * jax.lax.rsqrt(vv + eps)
            v_new = {"v": vv}
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay and p.ndim >= 2:
            u = u + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, v_new

    is_state_leaf = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    flat = jax.tree.map(upd, params, grads, state["v"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    # flat leaves are (p_new, v_new) tuples at param positions
    p_new = jax.tree.map(lambda pair: pair[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda pair: pair[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return p_new, {"step": step, "v": v_new}


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def make_optimizer(name: str, **kw) -> Tuple[Callable, Callable]:
    """Returns (init_fn, update_fn(grads, state, params, lr))."""
    if name == "adamw":
        return adamw_init, functools.partial(adamw_update, **kw)
    if name == "adafactor":
        return adafactor_init, functools.partial(adafactor_update, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
