"""Learning-rate schedules as step -> lr functions (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_schedule(base_lr: float, total_steps: int, *, final_frac=0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         *, final_frac=0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1),
                          final_frac=final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        return jnp.where(s < warmup, warm, cos(step - warmup))
    return fn


def linear_warmup_linear_decay(base_lr: float, warmup: int, total_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        frac = 1.0 - (s - warmup) / max(total_steps - warmup, 1)
        return jnp.where(s < warmup, warm, base_lr * jnp.clip(frac, 0.0, 1.0))
    return fn
