"""int8 gradient-compression all-reduce (opt-in, shard_map).

At 512+ chips the gradient all-reduce over the dp axes dominates step time
for small-per-chip-batch regimes. This module implements the standard
error-feedback int8 scheme:

  1. residual-corrected gradient g' = g + e          (error feedback)
  2. per-block scale s = max|g'| / 127, q = round(g' / s) ∈ int8
  3. all-reduce(q as int32 partial sums) + all-reduce(s) — 4× fewer wire
     bytes than f32 (int8 payload, scales are tiny)
  4. dequantize ĝ = mean(q) · mean(s); new residual e = g' − ĝ

Exposed as ``compressed_psum(tree, axes)`` for use inside shard_map-style
per-device code, and ``make_compressed_grad_fn`` which wraps a grads tree
after ``jax.grad`` in the data-parallel-only layout (the production trainer
flips it on with ``--grad-compression int8``).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g, *, block: int = 256):
    """g: any-shape f32 → (q int8 same shape, scales f32 (n_blocks,))."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    s = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(blocks / s[:, None]), -127, 127).astype(jnp.int8)
    return q, s, n


def dequantize_int8(q, s, n, shape):
    out = (q.astype(jnp.float32) * s[:, None]).reshape(-1)[:n]
    return out.reshape(shape)


def compressed_psum(g, axis_name, *, block: int = 256):
    """int8 psum of one array inside shard_map/pmap code."""
    q, s, n = quantize_int8(g, block=block)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(s, axis_name)
    world = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # mean of per-device dequantized grads ≈ dequant(mean q, mean s)
    return dequantize_int8(qsum.astype(jnp.float32) / world, ssum / world,
                           n, g.shape)


def compress_tree_for_allreduce(grads, residuals, *, block: int = 256):
    """Error-feedback quantization of a whole grads tree (device-local part).

    Returns (quantized tree of (q, s, n, shape), new_residuals) — the caller
    all-reduces q/s (e.g. via jax.lax.psum under shard_map) and calls
    ``decompress_tree``.
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(residuals)[0]
    out_q, out_res = [], []
    for g, e in zip(flat_g, flat_e):
        gc = g.astype(jnp.float32) + e
        q, s, n = quantize_int8(gc, block=block)
        deq = dequantize_int8(q, s, n, g.shape)
        out_q.append((q, s))
        out_res.append(gc - deq)
    qs = jax.tree_util.tree_unflatten(treedef, out_q)
    new_res = jax.tree_util.tree_unflatten(treedef, out_res)
    return qs, new_res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
