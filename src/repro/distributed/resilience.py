"""Fault tolerance at fleet scale: straggler detection + elastic planning.

On a real fleet these hooks sit in the trainer loop:

- :class:`StragglerMonitor` ingests per-host step heartbeats and flags hosts
  whose step latency exceeds a robust threshold (median + k·MAD) for several
  consecutive steps — the control plane then drains/replaces them.
- :class:`ElasticPlanner` decides, given the surviving host set, the largest
  valid mesh (dp must divide the global batch, tp must divide head/ff dims)
  and whether a restart-from-checkpoint is cheaper than limping.
- :func:`watchdog_step` wraps a jitted step with a wall-clock deadline so a
  hung collective surfaces as a timeout instead of a silent stall (on TPU
  fleets a hung NCCL/ICI collective is the classic failure mode).

All host-side logic (pure Python) — unit-testable without devices.
"""
from __future__ import annotations

import math
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class StragglerMonitor:
    def __init__(self, *, window: int = 20, mad_k: float = 5.0,
                 patience: int = 3):
        self.window = window
        self.mad_k = mad_k
        self.patience = patience
        self.latencies: Dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))
        self.strikes: Dict[str, int] = defaultdict(int)

    def record(self, host: str, step_seconds: float):
        self.latencies[host].append(step_seconds)

    def slow(self, host: str) -> bool:
        """Single-stream anomaly test: is ``host``'s LAST sample slow
        against its OWN recent window (median + k·MAD of the window)?

        :meth:`flagged` compares hosts against each other, which needs a
        fleet (≥ 2 streams). This variant serves the one-stream case —
        e.g. per-flush wall times in the streaming server, where "slow"
        means "slow relative to this process's own recent flushes". The
        MAD floor (5% of median) keeps a perfectly steady stream from
        flagging noise-level jitter. Needs half a window of history."""
        lat = self.latencies.get(host)
        if not lat or len(lat) < max(4, self.window // 2):
            return False
        hist = sorted(list(lat)[:-1])
        med = hist[len(hist) // 2]
        mad = sorted(abs(x - med) for x in hist)[len(hist) // 2]
        return lat[-1] > med + self.mad_k * max(mad, 0.05 * med, 1e-4)

    def _threshold(self) -> Optional[float]:
        last = [d[-1] for d in self.latencies.values() if d]
        if len(last) < 2:
            return None
        last_sorted = sorted(last)
        med = last_sorted[len(last_sorted) // 2]
        mad = sorted(abs(x - med) for x in last)[len(last) // 2]
        return med + self.mad_k * max(mad, 0.05 * med, 1e-4)

    def flagged(self) -> List[str]:
        """Hosts exceeding the robust threshold `patience` times in a row."""
        thr = self._threshold()
        if thr is None:
            return []
        out = []
        for host, lat in self.latencies.items():
            if lat and lat[-1] > thr:
                self.strikes[host] += 1
            else:
                self.strikes[host] = 0
            if self.strikes[host] >= self.patience:
                out.append(host)
        return sorted(out)


@dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    n_chips: int
    reason: str = ""


class ElasticPlanner:
    """Choose the largest valid (data, model) mesh for the surviving chips.

    model-axis candidates must divide ``tp_divisor`` (heads / d_ff / vocab
    GCD); data axis must keep ``global_batch`` divisible. Pods are atomic:
    losing any chip in a pod drops the pod (ICI is pod-internal).
    """

    def __init__(self, *, chips_per_pod: int = 256, tp_divisor: int = 16,
                 global_batch: int = 256):
        self.chips_per_pod = chips_per_pod
        self.tp_divisor = tp_divisor
        self.global_batch = global_batch

    def plan(self, healthy_pods: int) -> Optional[MeshPlan]:
        if healthy_pods <= 0:
            return None
        tp = min(self.tp_divisor, 16)
        per_pod_data = self.chips_per_pod // tp
        if healthy_pods == 1:
            return MeshPlan((per_pod_data, tp), ("data", "model"),
                            self.chips_per_pod, "single pod")
        dp = healthy_pods * per_pod_data
        if self.global_batch % healthy_pods != 0:
            # drop to the largest pod count that divides the batch
            while healthy_pods > 1 and self.global_batch % healthy_pods:
                healthy_pods -= 1
            return self.plan(healthy_pods)
        return MeshPlan((healthy_pods, per_pod_data, tp),
                        ("pod", "data", "model"),
                        healthy_pods * self.chips_per_pod,
                        f"{healthy_pods} pods")


def watchdog_step(fn, *args, deadline_s: float = 600.0):
    """Run a jitted step with a wall-clock deadline; raises TimeoutError.

    jax dispatch is async — we block on the first output leaf.
    """
    import jax

    t0 = time.time()
    out = fn(*args)
    leaves = jax.tree.leaves(out)
    if leaves:
        leaves[0].block_until_ready()
    dt = time.time() - t0
    if dt > deadline_s:
        raise TimeoutError(
            f"step exceeded deadline ({dt:.1f}s > {deadline_s}s) — "
            "likely hung collective / dead host")
    return out, dt
