"""Fault tolerance at fleet scale: straggler detection + elastic planning.

On a real fleet these hooks sit in the trainer loop:

- :class:`StragglerMonitor` ingests per-host step heartbeats and flags hosts
  whose step latency exceeds a robust threshold (median + k·MAD) for several
  consecutive steps — the control plane then drains/replaces them.
- :class:`ElasticPlanner` decides, given the surviving host set, the largest
  valid mesh (dp must divide the global batch, tp must divide head/ff dims)
  and whether a restart-from-checkpoint is cheaper than limping.
- :func:`watchdog_step` wraps a jitted step with a wall-clock deadline so a
  hung collective surfaces as a timeout instead of a silent stall (on TPU
  fleets a hung NCCL/ICI collective is the classic failure mode).

The SERVING side reuses the same module (DESIGN.md §15): the streaming
server feeds :class:`StragglerMonitor` with per-flush wall times, and the
mesh-sharded query engine feeds :class:`ShardHealth` with per-shard scan
timings so a failing shard degrades coverage instead of killing queries.

All host-side logic (pure Python) — unit-testable without devices.
"""
from __future__ import annotations

import math
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class StragglerMonitor:
    def __init__(self, *, window: int = 20, mad_k: float = 5.0,
                 patience: int = 3):
        self.window = window
        self.mad_k = mad_k
        self.patience = patience
        self.latencies: Dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))
        self.strikes: Dict[str, int] = defaultdict(int)

    def record(self, host: str, step_seconds: float):
        self.latencies[host].append(step_seconds)

    def slow(self, host: str) -> bool:
        """Single-stream anomaly test: is ``host``'s LAST sample slow
        against its OWN recent window (median + k·MAD of the window)?

        :meth:`flagged` compares hosts against each other, which needs a
        fleet (≥ 2 streams). This variant serves the one-stream case —
        e.g. per-flush wall times in the streaming server, where "slow"
        means "slow relative to this process's own recent flushes". The
        MAD floor (5% of median) keeps a perfectly steady stream from
        flagging noise-level jitter. Needs half a window of history."""
        lat = self.latencies.get(host)
        if not lat or len(lat) < max(4, self.window // 2):
            return False
        hist = sorted(list(lat)[:-1])
        med = hist[len(hist) // 2]
        mad = sorted(abs(x - med) for x in hist)[len(hist) // 2]
        return lat[-1] > med + self.mad_k * max(mad, 0.05 * med, 1e-4)

    def _threshold(self) -> Optional[float]:
        last = [d[-1] for d in self.latencies.values() if d]
        if len(last) < 2:
            return None
        last_sorted = sorted(last)
        med = last_sorted[len(last_sorted) // 2]
        mad = sorted(abs(x - med) for x in last)[len(last) // 2]
        return med + self.mad_k * max(mad, 0.05 * med, 1e-4)

    def flagged(self) -> List[str]:
        """Hosts exceeding the robust threshold `patience` times in a row."""
        thr = self._threshold()
        if thr is None:
            return []
        out = []
        for host, lat in self.latencies.items():
            if lat and lat[-1] > thr:
                self.strikes[host] += 1
            else:
                self.strikes[host] = 0
            if self.strikes[host] >= self.patience:
                out.append(host)
        return sorted(out)


class ShardUnavailable(RuntimeError):
    """No shard could serve the scan — every shard is DOWN/unscannable.

    A SINGLE lost shard never raises this: the engine serves the
    surviving partial top-k lists with a reduced coverage fraction
    (DESIGN.md §15). Only the total-loss case — zero partials to merge —
    surfaces as an error, because an empty result would be
    indistinguishable from "nothing matched"."""


class ShardHealth:
    """Per-shard serving health (DESIGN.md §15).

    Tracks, for each shard of the mesh-sharded index: an EWMA of scan
    wall-time (fed by timing every ``make_shard_topk_fn`` invocation), a
    consecutive-failure count, and an UP → SUSPECT → DOWN state machine:

    * UP → SUSPECT on the first scan failure;
    * SUSPECT → UP when a scan (device or host-replica) succeeds;
    * SUSPECT → DOWN after ``down_after`` consecutive failures, or
      immediately via :meth:`mark_down` (device lost);
    * DOWN is sticky: queries skip the shard (degraded coverage) until
      :meth:`mark_up` — only ``recover_shard`` flips it, after
      re-materializing the device part from the snapshot's global
      buffers. A lucky success must not mask a dead device.
    """

    UP, SUSPECT, DOWN = "up", "suspect", "down"

    def __init__(self, n_shards: int, *, alpha: float = 0.2,
                 down_after: int = 3):
        if n_shards < 1:
            raise ValueError(f"ShardHealth: n_shards={n_shards} < 1")
        if down_after < 1:
            raise ValueError(f"ShardHealth: down_after={down_after} < 1")
        self.n_shards = int(n_shards)
        self.alpha = float(alpha)
        self.down_after = int(down_after)
        self._ewma: List[Optional[float]] = [None] * self.n_shards
        self._failures: List[int] = [0] * self.n_shards
        self._states: List[str] = [self.UP] * self.n_shards

    def record_success(self, shard: int, seconds: float) -> None:
        prev = self._ewma[shard]
        self._ewma[shard] = (seconds if prev is None else
                             self.alpha * seconds
                             + (1.0 - self.alpha) * prev)
        self._failures[shard] = 0
        if self._states[shard] == self.SUSPECT:
            self._states[shard] = self.UP

    def record_failure(self, shard: int) -> str:
        """Count one failed scan; returns the new state."""
        self._failures[shard] += 1
        if self._states[shard] != self.DOWN:
            self._states[shard] = (
                self.DOWN if self._failures[shard] >= self.down_after
                else self.SUSPECT)
        return self._states[shard]

    def mark_down(self, shard: int) -> None:
        self._states[shard] = self.DOWN

    def mark_up(self, shard: int) -> None:
        """Recovery: reset the shard to a clean UP slate."""
        self._states[shard] = self.UP
        self._failures[shard] = 0
        self._ewma[shard] = None

    def state(self, shard: int) -> str:
        return self._states[shard]

    def is_down(self, shard: int) -> bool:
        return self._states[shard] == self.DOWN

    def ewma(self, shard: int) -> Optional[float]:
        return self._ewma[shard]

    def down_shards(self) -> Tuple[int, ...]:
        """Sorted DOWN set — the cache-key signature for degraded results."""
        return tuple(s for s in range(self.n_shards) if self.is_down(s))

    def snapshot(self) -> dict:
        """Metrics view (server.metrics() embeds it verbatim)."""
        return {
            "states": list(self._states),
            "ewma_s": list(self._ewma),
            "failures": list(self._failures),
            "down": list(self.down_shards()),
        }


@dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    n_chips: int
    reason: str = ""


class ElasticPlanner:
    """Choose the largest valid (data, model) mesh for the surviving chips.

    model-axis candidates must divide ``tp_divisor`` (heads / d_ff / vocab
    GCD); data axis must keep ``global_batch`` divisible. Pods are atomic:
    losing any chip in a pod drops the pod (ICI is pod-internal).
    """

    def __init__(self, *, chips_per_pod: int = 256, tp_divisor: int = 16,
                 global_batch: int = 256):
        self.chips_per_pod = chips_per_pod
        self.tp_divisor = tp_divisor
        self.global_batch = global_batch

    def plan(self, healthy_pods: int) -> Optional[MeshPlan]:
        if healthy_pods <= 0:
            return None
        tp = min(self.tp_divisor, 16)
        per_pod_data = self.chips_per_pod // tp
        if healthy_pods == 1:
            return MeshPlan((per_pod_data, tp), ("data", "model"),
                            self.chips_per_pod, "single pod")
        dp = healthy_pods * per_pod_data
        if self.global_batch % healthy_pods != 0:
            # drop to the largest pod count that divides the batch
            while healthy_pods > 1 and self.global_batch % healthy_pods:
                healthy_pods -= 1
            return self.plan(healthy_pods)
        return MeshPlan((healthy_pods, per_pod_data, tp),
                        ("pod", "data", "model"),
                        healthy_pods * self.chips_per_pod,
                        f"{healthy_pods} pods")


def watchdog_step(fn, *args, deadline_s: float = 600.0):
    """Run a jitted step with a wall-clock deadline; raises TimeoutError.

    jax dispatch is async — we block on the first output leaf.
    """
    import jax

    t0 = time.time()
    out = fn(*args)
    leaves = jax.tree.leaves(out)
    if leaves:
        leaves[0].block_until_ready()
    dt = time.time() - t0
    if dt > deadline_s:
        raise TimeoutError(
            f"step exceeded deadline ({dt:.1f}s > {deadline_s}s) — "
            "likely hung collective / dead host")
    return out, dt
