"""Logical→physical sharding rules.

Models annotate activations with *logical* axis names ("dp", "tp", None).
The launcher binds them to physical mesh axes for the active mesh:

  single-pod (16,16) ("data","model")      : dp=("data",)        tp=("model",)
  multi-pod  (2,16,16) ("pod","data","model"): dp=("pod","data") tp=("model",)

Outside any binding (CPU smoke tests) ``constrain`` is a no-op, so model code
is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: dict):
    """rules: {"dp": ("pod","data"), "tp": ("model",)}."""
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def rules_for_mesh(mesh) -> dict:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    tp = tuple(n for n in names if n == "model")
    return {"dp": dp, "tp": tp, "all": tuple(names),
            "_sizes": {n: mesh.shape[n] for n in names},
            "_mesh": mesh}


def logical_spec(*logical) -> Optional[P]:
    """Map logical axis names to a PartitionSpec under the current rules."""
    rules = current_rules()
    if rules is None:
        return None
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        else:
            phys = rules.get(ax, ())
            out.append(phys if len(phys) != 1 else phys[0])
    return P(*out)


def constrain(x, *logical):
    """with_sharding_constraint on logical axes; no-op without bound rules."""
    spec = logical_spec(*logical)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Parameter sharding rule tables (matched against pytree key paths).
# Shapes may carry extra leading "stacked scan" dims — rules give specs for
# the *trailing* dims; leading dims are padded with None.
# ---------------------------------------------------------------------------

# (regex on joined path, trailing logical axes)
LM_PARAM_RULES = (
    (r"embed$", ("tp", "dp")),                 # (V, d) vocab-parallel + fsdp
    (r"unembed$", ("dp", "tp")),               # (d, V)
    (r"attn/wq/w$", ("dp", "tp")),             # (d, H·Dh)
    (r"attn/wk/w$", ("dp", "tp")),
    (r"attn/wv/w$", ("dp", "tp")),
    (r"attn/wo/w$", ("tp", "dp")),             # (H·Dh, d)
    (r"attn/w[qkv]/b$", ("tp",)),
    (r"attn/wo/b$", ("dp",)),
    (r"moe/router$", (None, None)),            # small, replicated
    (r"moe/w1$", ("tp", "dp", None)),          # (E, d, f): EP + fsdp
    (r"moe/w3$", ("tp", "dp", None)),
    (r"moe/w2$", ("tp", None, "dp")),          # (E, f, d)
    (r"mlp/w1/w$", ("dp", "tp")),              # (d, f)
    (r"mlp/w3/w$", ("dp", "tp")),
    (r"mlp/w2/w$", ("tp", "dp")),              # (f, d)
    (r"mlp/w./b$", (None,)),
    (r"(ln|norm)", (None,)),                   # norms replicated
    (r"pos_embed$", (None, "dp")),
    (r".*", (None,)),                          # fallback: replicate
)

REC_PARAM_RULES = (
    (r"tables?(/\d+)?$", ("tp", None)),        # big embedding tables row-sharded
    (r"item_embed$", ("tp", None)),
    (r".*", (None,)),
)

GNN_PARAM_RULES = (
    (r".*", (None,)),                          # GatedGCN params are tiny
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_shape, rules_table, *, extra_leading=None):
    """Build a PartitionSpec pytree for a params shape-tree.

    extra_leading: optional fn(path_str) -> int giving the number of stacked
    scan dims to pad with None (default: inferred from rule length vs ndim).
    """
    rules = current_rules() or {}
    sizes = rules.get("_sizes", {})

    def _axes_size(entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        s = 1
        for n in names:
            s *= sizes.get(n, 1)
        return s

    def one(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        for pat, logical in rules_table:
            if re.search(pat, ps):
                logical = logical[:ndim]
                pad = ndim - len(logical)
                full = (None,) * pad + tuple(logical)
                spec = logical_spec(*full)
                if spec is None:
                    return None
                # divisibility guard: drop sharding on any dim the mesh
                # axes don't divide (e.g. odd-sized embedding tables)
                fixed = tuple(
                    e if leaf.shape[i] % _axes_size(e) == 0 else None
                    for i, e in enumerate(tuple(spec) + (None,) * (
                        ndim - len(tuple(spec)))))
                return P(*fixed)
        return logical_spec(*((None,) * ndim))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def named_shardings(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()), spec_tree,
        is_leaf=lambda s: s is None or isinstance(s, P))


def opt_state_specs(params_shapes, params_specs, optimizer: str):
    """PartitionSpec tree for the optimizer state, mirroring param specs.

    adamw: m/v shard exactly like the param. adafactor: vr drops the last
    dim of the param spec, vc drops the second-to-last (matching the
    factored second-moment shapes).
    """
    def _spec_tuple(s):
        return tuple(s) if s is not None else None

    if optimizer == "adamw":
        return {"step": P(), "m": params_specs, "v": params_specs}
    if optimizer == "adafactor":
        def leaf(p, s):
            st = _spec_tuple(s)
            factored = len(p.shape) >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1
            if not factored:
                return {"v": s}
            if st is None or len(st) < 2:
                return {"vr": None, "vc": None}
            return {"vr": P(*st[:-1]), "vc": P(*(st[:-2] + st[-1:]))}
        v = jax.tree.map(leaf, params_shapes, params_specs,
                         is_leaf=lambda x: hasattr(x, "shape"))
        return {"step": P(), "v": v}
    raise ValueError(optimizer)
