"""Logical→physical sharding rules.

Models annotate activations with *logical* axis names ("dp", "tp", None).
The launcher binds them to physical mesh axes for the active mesh:

  single-pod (16,16) ("data","model")      : dp=("data",)        tp=("model",)
  multi-pod  (2,16,16) ("pod","data","model"): dp=("pod","data") tp=("model",)

Outside any binding (CPU smoke tests) ``constrain`` is a no-op, so model code
is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
import warnings
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()

# the logical (and physical) axis name cluster buffers partition along
# for mesh-sharded serving (DESIGN.md §12)
CLUSTER_AXIS = "cluster"


def current_rules() -> Optional[dict]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: dict):
    """rules: {"dp": ("pod","data"), "tp": ("model",)}."""
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def rules_for_mesh(mesh) -> dict:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    tp = tuple(n for n in names if n == "model")
    cluster = tuple(n for n in names if n == CLUSTER_AXIS)
    return {"dp": dp, "tp": tp, "cluster": cluster, "all": tuple(names),
            "_sizes": {n: mesh.shape[n] for n in names},
            "_mesh": mesh}


def logical_spec(*logical) -> Optional[P]:
    """Map logical axis names to a PartitionSpec under the current rules."""
    rules = current_rules()
    if rules is None:
        return None
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        else:
            phys = rules.get(ax, ())
            out.append(phys if len(phys) != 1 else phys[0])
    return P(*out)


def constrain(x, *logical):
    """with_sharding_constraint on logical axes; no-op without bound rules."""
    spec = logical_spec(*logical)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Parameter sharding rule tables (matched against pytree key paths).
# Shapes may carry extra leading "stacked scan" dims — rules give specs for
# the *trailing* dims; leading dims are padded with None.
# ---------------------------------------------------------------------------

# (regex on joined path, trailing logical axes)
LM_PARAM_RULES = (
    (r"embed$", ("tp", "dp")),                 # (V, d) vocab-parallel + fsdp
    (r"unembed$", ("dp", "tp")),               # (d, V)
    (r"attn/wq/w$", ("dp", "tp")),             # (d, H·Dh)
    (r"attn/wk/w$", ("dp", "tp")),
    (r"attn/wv/w$", ("dp", "tp")),
    (r"attn/wo/w$", ("tp", "dp")),             # (H·Dh, d)
    (r"attn/w[qkv]/b$", ("tp",)),
    (r"attn/wo/b$", ("dp",)),
    (r"moe/router$", (None, None)),            # small, replicated
    (r"moe/w1$", ("tp", "dp", None)),          # (E, d, f): EP + fsdp
    (r"moe/w3$", ("tp", "dp", None)),
    (r"moe/w2$", ("tp", None, "dp")),          # (E, f, d)
    (r"mlp/w1/w$", ("dp", "tp")),              # (d, f)
    (r"mlp/w3/w$", ("dp", "tp")),
    (r"mlp/w2/w$", ("tp", "dp")),              # (f, d)
    (r"mlp/w./b$", (None,)),
    (r"(ln|norm)", (None,)),                   # norms replicated
    (r"pos_embed$", (None, "dp")),
    (r".*", (None,)),                          # fallback: replicate
)

REC_PARAM_RULES = (
    (r"tables?(/\d+)?$", ("tp", None)),        # big embedding tables row-sharded
    (r"item_embed$", ("tp", None)),
    (r".*", (None,)),
)

GNN_PARAM_RULES = (
    (r".*", (None,)),                          # GatedGCN params are tiny
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_shape, rules_table, *, extra_leading=None):
    """Build a PartitionSpec pytree for a params shape-tree.

    extra_leading: optional fn(path_str) -> int giving the number of stacked
    scan dims to pad with None (default: inferred from rule length vs ndim).
    """
    rules = current_rules() or {}
    sizes = rules.get("_sizes", {})

    def _axes_size(entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        s = 1
        for n in names:
            s *= sizes.get(n, 1)
        return s

    def one(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        for pat, logical in rules_table:
            if re.search(pat, ps):
                logical = logical[:ndim]
                pad = ndim - len(logical)
                full = (None,) * pad + tuple(logical)
                spec = logical_spec(*full)
                if spec is None:
                    return None
                # divisibility guard: drop sharding on any dim the mesh
                # axes don't divide (e.g. odd-sized embedding tables) —
                # and SAY so: a silently replicated dim looks identical
                # to a sharded one until a device runs out of memory
                padded = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
                fixed = tuple(
                    e if leaf.shape[i] % _axes_size(e) == 0 else None
                    for i, e in enumerate(padded))
                for i, (want, got) in enumerate(zip(padded, fixed)):
                    if want is not None and got is None:
                        warnings.warn(
                            f"param_specs: dropping sharding {want!r} on "
                            f"dim {i} of {ps!r} (shape {tuple(leaf.shape)}"
                            f"): {leaf.shape[i]} is not divisible by the "
                            f"mesh axes' size {_axes_size(want)}; the dim "
                            f"will be REPLICATED",
                            UserWarning, stacklevel=2)
                return P(*fixed)
        return logical_spec(*((None,) * ndim))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def named_shardings(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()), spec_tree,
        is_leaf=lambda s: s is None or isinstance(s, P))


# ---------------------------------------------------------------------------
# Mesh-sharded cluster buffers (serving scale-out, DESIGN.md §12).
#
# LIST's resident (c, cap, d) cluster buffers are the only state that
# grows with the corpus; router + relevance params are tiny and
# replicate. Partitioning is along the CLUSTER axis — the learned
# clustering stays intact under scale-out (WISK's argument), each shard
# holding whole clusters. ``shard_cluster_buffers`` resolves WHICH dims
# shard through the same logical-axis machinery as the training params
# (CLUSTER_BUFFER_RULES → param_specs → named_shardings), places the
# shard-stacked arrays, and hands back per-shard device-committed parts
# for the engine's per-shard plans (engine.make_shard_topk_fn).
# ---------------------------------------------------------------------------

# (regex on buffer key, trailing logical axes): every resident array
# partitions along its leading cluster axis; row contents stay local.
CLUSTER_BUFFER_RULES = (
    (r"emb$", (CLUSTER_AXIS, None, None)),     # (c, cap, d)
    (r"loc$", (CLUSTER_AXIS, None, None)),     # (c, cap, 2)
    (r"ids$", (CLUSTER_AXIS, None)),           # (c, cap)
    (r"scale$", (CLUSTER_AXIS, None)),         # (c, cap)
    (r"attrs$", (CLUSTER_AXIS, None, None)),   # (c, cap, 3)
    (r"counts$", (CLUSTER_AXIS,)),             # (c,)
    (r".*", (None,)),                          # anything else: replicate
)


def cluster_mesh(n_shards: int):
    """A 1-D mesh over the first ``n_shards`` local devices, physical
    axis named :data:`CLUSTER_AXIS`. On CPU, multi-device comes from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    jax imports) — that is how the mesh test tier runs on CI runners."""
    devs = jax.devices()
    if not (1 <= n_shards <= len(devs)):
        raise ValueError(
            f"cluster_mesh: n_shards={n_shards} needs 1..{len(devs)} "
            f"available devices (have {len(devs)}; on CPU raise the "
            f"count with XLA_FLAGS=--xla_force_host_platform_device_"
            f"count=N before jax is imported)")
    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), (CLUSTER_AXIS,))


def _as_cluster_mesh(mesh):
    if isinstance(mesh, (int, np.integer)):
        return cluster_mesh(int(mesh))
    if CLUSTER_AXIS not in mesh.axis_names:
        raise ValueError(
            f"shard_cluster_buffers: mesh axes {mesh.axis_names} carry "
            f"no {CLUSTER_AXIS!r} axis; build one with cluster_mesh(n)")
    return mesh


def cluster_buffer_specs(stacked: dict):
    """PartitionSpec tree for a dict of shard-stacked cluster-buffer
    arrays, resolved through :data:`CLUSTER_BUFFER_RULES` under the
    currently bound :func:`axis_rules`."""
    return param_specs(stacked, CLUSTER_BUFFER_RULES)


@dataclasses.dataclass(frozen=True)
class ClusterShards:
    """The placement record of one mesh-sharded set of cluster buffers.

    n_shards   shard (device) count
    c_global   real cluster count of the base buffers
    c_local    cluster rows per shard EXCLUDING the sentinel (the max
               group size; shards with fewer real clusters pad with
               empty ones — the ``c % n_shards`` remainder policy)
    shard_of   (c_global,) int32 — global cluster id → owning shard
    local_of   (c_global,) int32 — global cluster id → local buffer row
    parts      per-shard dicts of DEVICE-COMMITTED buffer arrays
               (emb/loc/ids/scale/attrs/counts), each shaped like a local
               buffer set of ``c_local + 1`` clusters: row ``c_local``
               is the SENTINEL empty cluster (ids −1 throughout) that
               off-shard routes localize to (serving.localize_routes),
               so every shard scores a full static-shape plan and
               off-shard candidates mask to NEG_INF exactly like
               padding slots
    devices    the mesh devices, parts[s] committed on devices[s]

    Placement only — query results are bit-identical to the unsharded
    buffers by the parity contract (DESIGN.md §12), which is why
    deriving one (IndexSnapshot.with_mesh) does NOT bump the snapshot
    version.
    """
    n_shards: int
    c_global: int
    c_local: int
    shard_of: np.ndarray
    local_of: np.ndarray
    parts: tuple
    devices: tuple

    @property
    def sentinel(self) -> int:
        """Local row index of the per-shard empty sentinel cluster."""
        return self.c_local

    def nbytes_per_device(self):
        """Resident buffer bytes committed per device (the scalability
        headline: ~1/n_shards of the unsharded footprint each)."""
        return [int(sum(np.asarray(a).nbytes for a in part.values()))
                for part in self.parts]


def shard_cluster_buffers(buffers: dict, mesh, *,
                          assignment=None) -> ClusterShards:
    """Partition packed cluster buffers cluster-major across a mesh.

    ``buffers`` is the dict of ``index.build_cluster_buffers`` (any
    precision tier — the storage dtypes ride along untouched); ``mesh``
    a shard count or a mesh carrying a :data:`CLUSTER_AXIS` axis;
    ``assignment`` an optional ``(c,)`` cluster→shard map (default:
    contiguous blocks of ``ceil(c / n_shards)`` clusters). Non-divisible
    ``c % n_shards`` is handled by padding short shards with EMPTY
    clusters, never by mis-sharding rows.

    Every shard's local buffers get one appended sentinel empty cluster
    (local row ``c_local``) so off-shard routes stay in-bounds under
    jit's clamped indexing — see :class:`ClusterShards`. Placement goes
    through the logical-axis machinery (:data:`CLUSTER_BUFFER_RULES` →
    :func:`param_specs` → :func:`named_shardings`): the shard-stacked
    arrays are ``device_put`` with the resolved NamedShardings and the
    per-device parts are their addressable shards — genuinely committed
    per device, which is what pins each per-shard plan's execution to
    its shard's device.
    """
    from repro.core import index as index_lib   # lazy: core imports us

    mesh = _as_cluster_mesh(mesh)
    n_shards = int(mesh.shape[CLUSTER_AXIS])
    host = {k: np.asarray(buffers[k])
            for k in ("emb", "loc", "ids", "scale", "counts")}
    if "attrs" in buffers:                 # attribute table is optional
        host["attrs"] = np.asarray(buffers["attrs"])
    c = host["ids"].shape[0]
    if assignment is None:
        per = -(-c // n_shards)
        assignment = (np.arange(c) // per).astype(np.int32)
    else:
        assignment = np.asarray(assignment, np.int32)
        if assignment.shape != (c,):
            raise ValueError(
                f"shard_cluster_buffers: assignment shape "
                f"{assignment.shape} != ({c},)")
        if assignment.size and (assignment.min() < 0
                                or assignment.max() >= n_shards):
            raise ValueError(
                f"shard_cluster_buffers: assignment values must lie in "
                f"[0, {n_shards}), got "
                f"[{assignment.min()}, {assignment.max()}]")
    groups = [np.flatnonzero(assignment == s) for s in range(n_shards)]
    c_local = max(1, max((len(g) for g in groups), default=1))
    local_of = np.zeros(c, np.int32)
    for g in groups:
        local_of[g] = np.arange(len(g), dtype=np.int32)

    # empty-cluster fill per key: exactly the buffer padding convention
    # (index.build_cluster_buffers / delete_objects), so a sentinel or
    # remainder-padding row scores NEG_INF through the same ids<0 mask
    fills = {"emb": 0, "loc": index_lib.PAD_LOC, "ids": -1, "scale": 1,
             "attrs": 0, "counts": 0}
    rows = c_local + 1                     # + the sentinel empty cluster
    stacked = {}
    for key, arr in host.items():
        if key == "counts":
            arr = arr.astype(np.int32)     # device arrays stay x32
        out = np.full((n_shards, rows) + arr.shape[1:], fills[key],
                      dtype=arr.dtype)
        for s, g in enumerate(groups):
            out[s, :len(g)] = arr[g]
        stacked[key] = out.reshape((n_shards * rows,) + arr.shape[1:])

    with axis_rules(rules_for_mesh(mesh)):
        specs = cluster_buffer_specs(stacked)
    for key, spec in specs.items():
        assert spec is not None and tuple(spec)[0] == CLUSTER_AXIS, (
            f"cluster rules failed to shard {key!r}: {spec}")
    shardings = named_shardings(mesh, specs)
    global_arrs = {k: jax.device_put(v, shardings[k])
                   for k, v in stacked.items()}

    # per-device parts = the addressable shards, in shard order (the
    # leading-dim slice start identifies which shard a piece is)
    parts = []
    for s in range(n_shards):
        parts.append({})
    for key, arr in global_arrs.items():
        pieces = sorted(arr.addressable_shards,
                        key=lambda sh: sh.index[0].start or 0)
        assert len(pieces) == n_shards, (key, len(pieces), n_shards)
        for s, piece in enumerate(pieces):
            parts[s][key] = piece.data
    devices = tuple(np.asarray(mesh.devices).flat)
    return ClusterShards(
        n_shards=n_shards, c_global=c, c_local=c_local,
        shard_of=assignment, local_of=local_of,
        parts=tuple(parts), devices=devices)


def opt_state_specs(params_shapes, params_specs, optimizer: str):
    """PartitionSpec tree for the optimizer state, mirroring param specs.

    adamw: m/v shard exactly like the param. adafactor: vr drops the last
    dim of the param spec, vc drops the second-to-last (matching the
    factored second-moment shapes).
    """
    def _spec_tuple(s):
        return tuple(s) if s is not None else None

    if optimizer == "adamw":
        return {"step": P(), "m": params_specs, "v": params_specs}
    if optimizer == "adafactor":
        def leaf(p, s):
            st = _spec_tuple(s)
            factored = len(p.shape) >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1
            if not factored:
                return {"v": s}
            if st is None or len(st) < 2:
                return {"vr": None, "vc": None}
            return {"vr": P(*st[:-1]), "vc": P(*(st[:-2] + st[-1:]))}
        v = jax.tree.map(leaf, params_shapes, params_specs,
                         is_leaf=lambda x: hasattr(x, "shape"))
        return {"step": P(), "v": v}
    raise ValueError(optimizer)
